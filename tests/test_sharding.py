"""Multi-device correctness, run in a subprocess with 8 fake CPU devices so
the rest of the suite keeps seeing 1 device.

Checks that sharded execution is NUMERICALLY IDENTICAL to single-device:
train step on a 2x4 (data, model) mesh (incl. shard_map MoE) and the
sharded paged-attention decode inner.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    import sys
    sys.path.insert(0, "SRC")

    from repro import optim
    from repro.configs import ARCHS, reduced, replace
    from repro.configs.base import MoEConfig
    from repro.models import transformer as T
    from repro.train import TrainConfig, make_train_step, make_shardings

    assert jax.device_count() == 8

    # -- sharded vs single-device train step (MoE arch, exercises EP) -------
    cfg = reduced(ARCHS["deepseek-moe-16b"])
    cfg = replace(cfg, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16)
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    B, S = 4, 32
    toks = jax.random.randint(key, (2, B // 2, S), 0, cfg.vocab)
    labels = jax.random.randint(jax.random.PRNGKey(1), (2, B // 2, S), 0,
                                cfg.vocab)
    tcfg = TrainConfig(microbatches=2, compute_dtype=jnp.float32, zero1=True,
                       adamw=optim.AdamWConfig(lr=1e-3))

    # single device
    ctx1 = T.ParallelCtx(remat=False, q_block=16, kv_block=16, loss_chunk=16,
                         compute_dtype=jnp.float32)
    step1 = make_train_step(cfg, ctx1, tcfg)
    opt = optim.init(params)
    p1, o1, m1 = jax.jit(step1)(params, opt, toks, labels)

    # 2x4 mesh
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx2 = T.ParallelCtx(mesh=mesh, dp_axes=("data",), remat=False,
                         q_block=16, kv_block=16, loss_chunk=16,
                         compute_dtype=jnp.float32)
    step2 = make_train_step(cfg, ctx2, tcfg)
    pshape = jax.eval_shape(lambda: params)
    ins, outs = make_shardings(cfg, ctx2, tcfg, pshape)
    with mesh:
        p2, o2, m2 = jax.jit(step2, in_shardings=ins,
                             out_shardings=outs)(params, opt, toks, labels)

    loss1, loss2 = float(m1["loss"]), float(m2["loss"])
    assert abs(loss1 - loss2) < 1e-3, (loss1, loss2)
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), p1, p2)
    maxdiff = max(jax.tree.leaves(d))
    assert maxdiff < 1e-3, maxdiff
    print("TRAIN_OK", loss1, loss2, maxdiff)

    # -- sharded paged decode inner vs local reference -----------------------
    from repro.configs import get_shape
    from repro.launch.serve_step import (_paged_attn_sharded, DecodePlan)
    from repro.models.attention import decode_partial, combine_partials

    plan = DecodePlan(batch_axes=("data",), kv_axes=("model",), page=4)
    Bq, Hq, Hkv, D, page, P_loc, slots = 4, 4, 2, 16, 4, 3, 8
    kvr, dp = 4, 2
    rng = np.random.default_rng(0)
    pool_k = jnp.asarray(rng.normal(size=(dp, kvr, slots, page, Hkv, D)),
                         jnp.float32)
    pool_v = jnp.asarray(rng.normal(size=(dp, kvr, slots, page, Hkv, D)),
                         jnp.float32)
    q = jnp.asarray(rng.normal(size=(Bq, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(Bq, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(Bq, Hkv, D)), jnp.float32)
    lengths = jnp.asarray([37, 30, 21, 14], jnp.int32)
    # block tables: page j of seq b lives on rank j%kvr, slot = deterministic
    bt = np.full((dp, kvr, Bq // dp, P_loc), -1, np.int32)
    app_rank = np.zeros(Bq, np.int32)
    app_slot = np.zeros(Bq, np.int32)
    app_off = np.zeros(Bq, np.int32)
    for b in range(Bq):
        n_pages = int(lengths[b]) // page + 1
        for pg in range(n_pages):
            r, j = pg % kvr, pg // kvr
            bt[b // (Bq // dp), r, b % (Bq // dp), j] = (b + pg) % slots
        cur = int(lengths[b])
        pgc = cur // page
        app_rank[b] = pgc % kvr
        app_slot[b] = (b + pgc) % slots
        app_off[b] = cur % page

    with mesh:
        cache = {"pool_k": pool_k, "pool_v": pool_v}
        upd, out = jax.jit(lambda c, *a: _paged_attn_sharded(
            c, *a, mesh=mesh, plan=plan, page=page, out_dtype=jnp.float32))(
            cache, jnp.asarray(bt), q, k, v,
            jnp.asarray(app_slot), jnp.asarray(app_off),
            jnp.asarray(app_rank), lengths)

    # reference: emulate append + gather per sequence
    pool_k_ref = np.array(pool_k)
    pool_v_ref = np.array(pool_v)
    for b in range(Bq):
        di = b // (Bq // dp)
        pool_k_ref[di, app_rank[b], app_slot[b], app_off[b]] = k[b]
        pool_v_ref[di, app_rank[b], app_slot[b], app_off[b]] = v[b]
    outs_ref = []
    for b in range(Bq):
        di, bl = b // (Bq // dp), b % (Bq // dp)
        keys, vals, valid = [], [], []
        n_pages = int(lengths[b]) // page + 1
        for pg in range(n_pages):
            r, j = pg % kvr, pg // kvr
            s = bt[di, r, bl, j]
            keys.append(pool_k_ref[di, r, s])
            vals.append(pool_v_ref[di, r, s])
            base = pg * page
            valid.append((np.arange(page) + base) <= int(lengths[b]))
        keys = jnp.asarray(np.concatenate(keys))[None]
        vals = jnp.asarray(np.concatenate(vals))[None]
        vmask = jnp.asarray(np.concatenate(valid))[None]
        m, l, a = decode_partial(q[b:b+1], keys, vals, vmask)
        outs_ref.append(combine_partials((m[None], l[None], a[None]),
                                         jnp.float32)[0])
    ref = jnp.stack(outs_ref).reshape(Bq, Hq, D)
    err = float(jnp.abs(out.reshape(Bq, Hq, D) - ref).max())
    assert err < 1e-4, err
    print("DECODE_OK", err)

    # int8 quantized pool: same attention within quantization tolerance
    plan8 = DecodePlan(batch_axes=("data",), kv_axes=("model",), page=4,
                       kv_dtype="int8")
    from repro.launch.serve_step import _quantize_token
    pk_q = np.zeros((dp, kvr, slots, page, Hkv, D), np.int8)
    sk_q = np.zeros((dp, kvr, slots, page, Hkv), np.float32)
    pv_q = np.zeros_like(pk_q)
    sv_q = np.zeros_like(sk_q)
    for di in range(dp):
        for r in range(kvr):
            for s_ in range(slots):
                kq, ks = _quantize_token(pool_k[di, r, s_])
                vq, vs = _quantize_token(pool_v[di, r, s_])
                pk_q[di, r, s_] = np.asarray(kq)
                sk_q[di, r, s_] = np.asarray(ks)
                pv_q[di, r, s_] = np.asarray(vq)
                sv_q[di, r, s_] = np.asarray(vs)
    with mesh:
        cache8 = {"pool_k": jnp.asarray(pk_q), "pool_v": jnp.asarray(pv_q),
                  "scale_k": jnp.asarray(sk_q), "scale_v": jnp.asarray(sv_q)}
        upd8, out8 = jax.jit(lambda c, *a: _paged_attn_sharded(
            c, *a, mesh=mesh, plan=plan8, page=page,
            out_dtype=jnp.float32))(
            cache8, jnp.asarray(bt), q, k, v,
            jnp.asarray(app_slot), jnp.asarray(app_off),
            jnp.asarray(app_rank), lengths)
    err8 = float(jnp.abs(out8.reshape(Bq, Hq, D) - ref).max())
    assert err8 < 0.08, err8
    print("DECODE_INT8_OK", err8)
""").replace("SRC", os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.mark.slow
def test_sharded_execution_matches_single_device(tmp_path):
    script = tmp_path / "sharded_check.py"
    script.write_text(SCRIPT)
    res = subprocess.run([sys.executable, str(script)], capture_output=True,
                         text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "TRAIN_OK" in res.stdout
    assert "DECODE_OK" in res.stdout
    assert "DECODE_INT8_OK" in res.stdout
