"""End-to-end behaviour: train a small model on the synthetic task, serve it
through the Valet engine under memory pressure, and confirm the generated
text is identical to a pressure-free run while baselines pay their costs."""
import numpy as np

import jax
import jax.numpy as jnp

from repro import optim
from repro.configs import ARCHS, reduced
from repro.core.policies import POLICIES
from repro.data import DataConfig, TrainDataset
from repro.models import transformer as T
from repro.serve import ValetServeEngine
from repro.train import TrainConfig, ValetCheckpointer, fit

CTX = T.ParallelCtx(remat=False, q_block=16, kv_block=16, loss_chunk=16,
                    compute_dtype=jnp.float32)


def test_train_checkpoint_serve_roundtrip(tmp_path):
    cfg = reduced(ARCHS["gemma3-4b"])
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tcfg = TrainConfig(microbatches=2, compute_dtype=jnp.float32,
                       adamw=optim.AdamWConfig(lr=1e-3, warmup_steps=5,
                                               total_steps=30))
    ds = TrainDataset(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8))

    ckpt = ValetCheckpointer(str(tmp_path), replicas=2)
    params, opt_state, hist = fit(params, cfg, CTX, tcfg, ds, n_steps=25,
                                  log_every=5)
    assert hist[-1]["loss"] < hist[0]["loss"]
    ckpt.save(25, params)
    ckpt.wait()

    # restart from checkpoint (fault-tolerance path)
    step, restored = ckpt.restore(tree_like=params)
    assert step == 25
    same = jax.tree.map(lambda a, b: bool((np.asarray(a) ==
                                           np.asarray(b)).all()),
                        params, restored)
    assert all(jax.tree.leaves(same))
    ckpt.close()

    # serve the trained model under pool pressure; outputs must match the
    # unconstrained engine exactly (Valet) and complete for baselines
    rng = np.random.default_rng(1)
    prompts = [rng.integers(2, cfg.vocab, size=8) for _ in range(4)]

    def run(policy, slots):
        eng = ValetServeEngine(restored, cfg, CTX, max_batch=2, max_seq=48,
                               page=4, pool_slots=slots,
                               policy=POLICIES[policy])
        for p in prompts:
            eng.submit(p, max_new=8)
        reqs = eng.run(max_steps=400)
        assert all(r.status == "done" for r in reqs)
        return [r.tokens_out for r in sorted(reqs, key=lambda r: r.rid)], \
            eng.stats

    ref, _ = run("valet", slots=64)
    valet_out, valet_stats = run("valet", slots=5)
    assert valet_out == ref
    assert valet_stats.spilled_pages > 0          # pressure actually hit
    inf_out, inf_stats = run("infiniswap", slots=5)
    assert inf_out == ref
    assert inf_stats.sim_time_us > valet_stats.sim_time_us
