"""Training substrate: loss decreases, checkpoint fault tolerance,
deterministic data, elastic recovery plans."""
import os

import numpy as np

import jax
import jax.numpy as jnp

from repro import optim
from repro.configs import ARCHS, reduced
from repro.data import DataConfig, TrainDataset, batch_for_step
from repro.models import transformer as T
from repro.train import (TrainConfig, ValetCheckpointer, fit,
                         ClusterSpec, degraded_mesh_shape,
                         make_recovery_plan)

CTX = T.ParallelCtx(remat=False, q_block=16, kv_block=16, loss_chunk=16,
                    compute_dtype=jnp.float32)


def test_loss_decreases():
    cfg = reduced(ARCHS["phi3-mini-3.8b"])
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tcfg = TrainConfig(microbatches=2, compute_dtype=jnp.float32,
                       adamw=optim.AdamWConfig(lr=1e-3, warmup_steps=5,
                                               total_steps=60))
    ds = TrainDataset(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8))
    _, _, hist = fit(params, cfg, CTX, tcfg, ds, n_steps=40, log_every=10)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.1


def test_data_determinism_and_reshard():
    cfg = DataConfig(vocab=128, seq_len=16, global_batch=8, seed=3)
    a1, b1 = batch_for_step(cfg, step=5, shard=0, n_shards=2)
    a2, b2 = batch_for_step(cfg, step=5, shard=0, n_shards=2)
    np.testing.assert_array_equal(a1, a2)
    # resharding keeps the stream position
    ds = TrainDataset(cfg, shard=0, n_shards=2, start_step=7)
    ds2 = ds.reshard(shard=1, n_shards=4)
    assert ds2.step == 7 and ds2.n_shards == 4
    # labels are next-token shifted
    np.testing.assert_array_equal(a1[:, 1:], b1[:, :-1])


def test_checkpointer_async_restore(tmp_path):
    ckpt = ValetCheckpointer(str(tmp_path), replicas=2, keep=2)
    tree = {"w": np.arange(10, dtype=np.float32),
            "b": {"x": np.ones((3, 3), np.float32)}}
    dt = ckpt.save(1, tree)
    assert dt < 1.0                       # staging is the only critical path
    tree["w"] = tree["w"] + 1
    ckpt.save(2, tree)
    ckpt.wait()
    step, restored = ckpt.restore()
    assert step == 2
    np.testing.assert_array_equal(restored["w"], tree["w"])
    ckpt.close()


def test_checkpointer_replica_failover(tmp_path):
    ckpt = ValetCheckpointer(str(tmp_path), replicas=2)
    tree = {"w": np.arange(6, dtype=np.float32)}
    ckpt.save(3, tree)
    ckpt.wait()
    # corrupt replica 0 (primary): restore must fall back to replica 1
    r0 = os.path.join(str(tmp_path), "replica0", "step_00000003",
                      "arrays.npz")
    with open(r0, "wb") as f:
        f.write(b"garbage")
    step, restored = ckpt.restore()
    assert step == 3
    np.testing.assert_array_equal(restored["w"], tree["w"])
    ckpt.close()


def test_checkpointer_skips_stale_snapshots(tmp_path):
    """Update-flag semantics: a newer staged snapshot supersedes older."""
    ckpt = ValetCheckpointer(str(tmp_path), replicas=1)
    for s in range(6):
        ckpt.save(s, {"w": np.full(4, s, np.float32)})
    ckpt.wait()
    step, restored = ckpt.restore()
    assert step == 5
    np.testing.assert_array_equal(restored["w"], np.full(4, 5, np.float32))
    ckpt.close()


def test_elastic_degraded_mesh():
    spec = ClusterSpec(n_pods=2, data_parallel=16, model_parallel=16)
    # lose 20 devices: TP stays 16, DP shrinks
    d = degraded_mesh_shape(spec, spec.n_devices - 20)
    assert d is not None and d.model_parallel == 16
    assert d.n_devices <= spec.n_devices - 20 + 16
    # catastrophic loss
    assert degraded_mesh_shape(spec, 7) is None


def test_recovery_plan():
    spec = ClusterSpec(n_pods=1, data_parallel=4, model_parallel=4)
    plan = make_recovery_plan(spec, alive_devices=list(range(9)),
                              restore_step=123)
    assert plan is not None
    assert plan["restore_step"] == 123
    assert len(plan["devices_used"]) == plan["mesh"].n_devices
    assert all(step == 123 for _, step in plan["data_shards"])


def test_grad_compression_bf16_matches_fp32_closely():
    """bf16 gradient all-reduce (compression) stays close to fp32 grads."""
    cfg = reduced(ARCHS["h2o-danube-3-4b"])
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    ds = TrainDataset(DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4))
    toks, labels = next(ds)
    out = {}
    for dtype in (jnp.float32, jnp.bfloat16):
        tcfg = TrainConfig(microbatches=2, compute_dtype=jnp.float32,
                           grad_dtype=dtype,
                           adamw=optim.AdamWConfig(lr=1e-3))
        from repro.train import make_train_step
        fn = make_train_step(cfg, CTX, tcfg)
        opt = optim.init(params)
        t = jnp.asarray(toks).reshape(2, 2, -1)
        l = jnp.asarray(labels).reshape(2, 2, -1)
        newp, _, m = fn(params, opt, t, l)
        out[str(dtype)] = float(m["grad_norm"])
    a, b = out.values()
    assert abs(a - b) / max(a, 1e-9) < 0.05
