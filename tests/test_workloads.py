"""Trace-driven workload suite (repro.data.workloads): determinism, YCSB
mix ratios, hotset-rotation phase shifts, ML sweep structure, mixed-tenant
op conservation, and a small end-to-end replay determinism check."""
import numpy as np
import pytest

from repro.data.workloads import (MLTraceConfig, MixedTenantConfig,
                                  WorkloadTrace, YCSBConfig, YCSB_MIXES,
                                  interleave_tenants, mixed_tenant_traces,
                                  ml_trace, phase_segments, ycsb_trace)


# -- determinism --------------------------------------------------------------

@pytest.mark.parametrize("letter", sorted(YCSB_MIXES))
def test_ycsb_deterministic_under_fixed_seed(letter):
    a = ycsb_trace(YCSBConfig(letter, seed=5))
    b = ycsb_trace(YCSBConfig(letter, seed=5))
    np.testing.assert_array_equal(a.pages, b.pages)
    np.testing.assert_array_equal(a.is_write, b.is_write)
    c = ycsb_trace(YCSBConfig(letter, seed=6))
    assert not np.array_equal(a.pages, c.pages), "seed must matter"


def test_ml_and_mixed_deterministic_under_fixed_seed():
    a, b = ml_trace(MLTraceConfig(seed=3)), ml_trace(MLTraceConfig(seed=3))
    np.testing.assert_array_equal(a.pages, b.pages)
    np.testing.assert_array_equal(a.is_write, b.is_write)
    ta = mixed_tenant_traces(MixedTenantConfig())
    tb = mixed_tenant_traces(MixedTenantConfig())
    for x, y in zip(ta, tb):
        np.testing.assert_array_equal(x.pages, y.pages)
        np.testing.assert_array_equal(x.is_write, y.is_write)


# -- YCSB mix ratios ----------------------------------------------------------

@pytest.mark.parametrize("letter", sorted(YCSB_MIXES))
def test_ycsb_mix_ratio_matches_spec(letter):
    cfg = YCSBConfig(letter, n_ops=40_000, seed=1)
    trace = ycsb_trace(cfg)
    spec_read = YCSB_MIXES[letter]["read"]
    assert trace.read_fraction() == pytest.approx(spec_read, abs=0.01)
    assert len(trace) == cfg.n_ops
    assert trace.pages.min() >= 0
    assert trace.pages.max() < cfg.n_pages


def test_ycsb_c_is_strictly_read_only():
    assert not ycsb_trace(YCSBConfig("C", seed=2)).is_write.any()


# -- hotset rotation ----------------------------------------------------------

def _top_pages(pages, k=50):
    vals, cnt = np.unique(pages, return_counts=True)
    return set(vals[np.argsort(-cnt)[:k]].tolist())


def test_hotset_rotation_shifts_the_hot_set():
    """Each rotation phase's most-frequent pages must be (almost) disjoint
    from the previous phase's — that is the point of rotation."""
    cfg = YCSBConfig("B", n_ops=40_000, n_phases=4, seed=4)
    trace = ycsb_trace(cfg)
    assert len(trace.phase_bounds) == cfg.n_phases - 1
    cuts = [0, *trace.phase_bounds, len(trace)]
    hotsets = [_top_pages(trace.pages[s:e])
               for s, e in zip(cuts[:-1], cuts[1:])]
    for h0, h1 in zip(hotsets[:-1], hotsets[1:]):
        overlap = len(h0 & h1) / len(h0)
        assert overlap < 0.2, f"hot set did not rotate: overlap={overlap}"


def test_ycsb_d_hot_set_drifts_toward_latest_inserts():
    """Workload D's reads skew to recently inserted keys, so the hot set of
    the last quarter of the trace sits at higher key ids than the first's
    (before any wrap: keyspace starts half-full)."""
    cfg = YCSBConfig("D", n_ops=20_000, n_pages=4096, seed=4)
    trace = ycsb_trace(cfg)
    assert int(trace.is_write.sum()) < cfg.n_pages // 2, "no wrap expected"
    q = len(trace) // 4
    early = np.median(trace.pages[:q])
    late = np.median(trace.pages[-q:])
    assert late > early


# -- ML working-set trace -----------------------------------------------------

def test_ml_trace_forward_write_backward_read_sweeps():
    cfg = MLTraceConfig(n_steps=2, total_pages=512, seed=0)
    trace = ml_trace(cfg)
    # 2 sweeps per step, bounds between each
    assert len(trace.phase_bounds) == 2 * cfg.n_steps - 1
    cuts = [0, *trace.phase_bounds, len(trace)]
    segs = list(zip(cuts[:-1], cuts[1:]))
    for i, (s, e) in enumerate(segs):
        sweep_writes = trace.is_write[s:e]
        if i % 2 == 0:                      # forward sweep
            assert sweep_writes.all()
        else:                               # backward sweep
            assert not sweep_writes.any()
        # every sweep touches the whole activation working set exactly once
        np.testing.assert_array_equal(np.sort(trace.pages[s:e]),
                                      np.arange(trace.n_pages))
    # forward order ascends by layer; backward starts from the last layer
    fwd, bwd = segs[0], segs[1]
    assert trace.pages[fwd[0]] == 0
    assert trace.pages[bwd[0]] > trace.n_pages // 2


def test_ml_trace_sized_off_the_model_zoo():
    small = ml_trace(MLTraceConfig(arch="gemma3-4b", total_pages=256))
    big = ml_trace(MLTraceConfig(arch="gemma3-4b", total_pages=1024))
    # per-layer rounding (>=1 page per layer) may overshoot a little
    assert small.n_pages == pytest.approx(256, rel=0.1)
    assert big.n_pages == pytest.approx(1024, rel=0.1)
    with pytest.raises(KeyError):
        ml_trace(MLTraceConfig(arch="not-a-real-arch"))


# -- mixed tenants ------------------------------------------------------------

def test_mixed_tenant_conserves_per_tenant_op_counts():
    cfg = MixedTenantConfig()
    traces = mixed_tenant_traces(cfg)
    n_tenants = len(cfg.kv) + len(cfg.ml)
    assert len(traces) == n_tenants
    for t, trace in enumerate(traces):
        segs = phase_segments(trace)
        assert len(segs) == n_tenants
        # segments tile the trace exactly: no op lost, none duplicated
        assert segs[0][0] == 0 and segs[-1][1] == len(trace)
        for (_, e0), (s1, _) in zip(segs[:-1], segs[1:]):
            assert e0 == s1
        # the hot segment carries the tenant's full workload trace
        hot_s, hot_e = segs[t]
        if t < len(cfg.kv):
            assert hot_e - hot_s == cfg.kv[t].n_ops
            # cold phases are the keyspace-head trickle
            for p, (s, e) in enumerate(segs):
                if p != t:
                    assert e - s == cfg.idle_ops
                    assert trace.pages[s:e].max() < cfg.idle_pages
        else:
            ml_len = len(ml_trace(cfg.ml[t - len(cfg.kv)]))
            assert hot_e - hot_s == ml_len
            for p, (s, e) in enumerate(segs):
                if p != t:
                    assert e == s, "ML tenants are silent off-phase"


def test_churn_tenants_conserve_ops_within_their_lifetime():
    """Churn tenants (cluster-scale PR satellite) behave like KV tenants
    inside their ``tenant_lifetimes`` window and emit empty segments
    outside it, so op conservation over the interleaved schedule holds
    with churn enabled."""
    from repro.data.workloads import tenant_lifetimes
    cfg = MixedTenantConfig(churn_kv=(
        YCSBConfig("B", n_pages=256, n_ops=2_000, seed=40),))
    n_base = len(cfg.kv) + len(cfg.ml)
    n_tenants = n_base + 1
    lifetimes = tenant_lifetimes(cfg)
    # base tenants live the whole run; the churn tenant joins one phase
    # before its hot phase (= its own index) and leaves one after
    assert lifetimes[:n_base] == [(0, n_tenants)] * n_base
    assert lifetimes[n_base] == (n_base - 1, n_tenants)
    traces = mixed_tenant_traces(cfg)
    assert len(traces) == n_tenants
    churn = traces[n_base]
    segs = phase_segments(churn)
    assert len(segs) == n_tenants
    join, leave = lifetimes[n_base]
    for ph, (s, e) in enumerate(segs):
        if ph == n_base:                     # hot phase: the full trace
            assert e - s == cfg.churn_kv[0].n_ops
        elif join <= ph < leave:             # linger: keyspace-head trickle
            assert e - s == cfg.idle_ops
            assert churn.pages[s:e].max() < cfg.idle_pages
        else:                                # dead: not a single op
            assert e == s
    # conservation: the interleaved schedule drives exactly every op
    sched = interleave_tenants([len(t) for t in traces], cfg.slice_ops)
    for t, trace in enumerate(traces):
        assert sum(e - s for tt, s, e in sched if tt == t) == len(trace)


def test_churn_lifetime_windows_clamp_to_the_run():
    """Linger windows never extend past the run: wide margins clamp to
    ``[0, n_tenants)`` instead of inventing phantom phases."""
    from repro.data.workloads import tenant_lifetimes
    cfg = MixedTenantConfig(
        churn_kv=(YCSBConfig("A", n_ops=500, seed=41),
                  YCSBConfig("B", n_ops=500, seed=42)),
        churn_linger_phases=10)
    n_base = len(cfg.kv) + len(cfg.ml)
    lifetimes = tenant_lifetimes(cfg)
    n_tenants = n_base + 2
    for join, leave in lifetimes:
        assert 0 <= join < leave <= n_tenants
    assert lifetimes[n_base:] == [(0, n_tenants)] * 2
    # negative margins are treated as zero: live exactly in the hot phase
    tight = tenant_lifetimes(MixedTenantConfig(
        churn_kv=(YCSBConfig("A", n_ops=500, seed=41),),
        churn_linger_phases=-3))
    assert tight[n_base] == (n_base, n_base + 1)


def test_empty_churn_config_is_bitwise_identical_to_default():
    """``churn_kv=()`` (the default) must leave the suite untouched —
    same lifetimes, and every emitted trace bitwise identical."""
    from repro.data.workloads import tenant_lifetimes
    plain, explicit = MixedTenantConfig(), MixedTenantConfig(churn_kv=())
    assert tenant_lifetimes(plain) == tenant_lifetimes(explicit)
    for a, b in zip(mixed_tenant_traces(plain),
                    mixed_tenant_traces(explicit)):
        np.testing.assert_array_equal(a.pages, b.pages)
        np.testing.assert_array_equal(a.is_write, b.is_write)
        assert a.phase_bounds == b.phase_bounds


def test_interleave_schedule_conserves_and_reorders_nothing():
    lengths = [1000, 257, 0, 513]
    sched = interleave_tenants(lengths, slice_ops=128)
    for t, n in enumerate(lengths):
        slices = [(s, e) for tt, s, e in sched if tt == t]
        assert sum(e - s for s, e in slices) == n
        # in order and gapless
        pos = 0
        for s, e in slices:
            assert s == pos and e > s
            pos = e
        assert pos == n
    with pytest.raises(ValueError):
        interleave_tenants([10], 0)


# -- end-to-end replay determinism -------------------------------------------

def test_workload_replay_is_deterministic_through_the_store():
    """Two replays of the same trace through fresh stores produce identical
    simulated stats — the property the CI workload gates rely on."""
    from repro.core import (OrchestrationConfig, POLICIES, PAPER_COSTS,
                            TieredPageStore)

    trace = ycsb_trace(YCSBConfig("A", n_pages=256, n_ops=4000, seed=9))

    def run():
        st = TieredPageStore.from_config(OrchestrationConfig(
            policy=POLICIES["valet"], costs=PAPER_COSTS,
            pool_capacity=64, min_pool=64, max_pool=64,
            n_peers=4, peer_capacity_blocks=256, pages_per_block=16,
            seed=0))
        st.access_batch(np.arange(trace.n_pages), np.ones(trace.n_pages,
                                                          bool))
        st.drain()
        st.access_batch(trace.pages, trace.is_write)
        return st.stats

    assert run() == run()
