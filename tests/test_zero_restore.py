"""Zero-restore serving (PR 8): the device KV pool as a first-class tier.

Pins the tentpole's contract from three sides:

* **Decode parity** — with the same pressure trace, zero-restore and the
  legacy bulk spill/restore produce bit-identical outputs for every policy
  (and the flag is inert for os-swap/infiniswap, whose eager/delete
  behavior defines those baselines).
* **No bulk copy on the repoint path** — restores in zero-restore mode
  never touch the bulk ``local_write_batch`` scatter; a run under pressure
  restores pages while the bulk primitive stays uncalled (the same counter
  shows the legacy engine does call it, so the assertion has teeth).
* **Tier/pool primitives** — the pool's generation counter and
  ``claim_batch``, the ``DeviceTier`` shadow lifecycle, and the trace
  store's opt-in device tier (verified by the ``InvariantChecker``, like
  async mode — repoints deliberately change hit classification, so this
  mode trades bitwise scalar/batch parity for invariants).
"""
import numpy as np
import pytest

import jax

from repro.configs import ARCHS, reduced
from repro.core import (DeviceTier, InvariantChecker, OrchestrationConfig,
                        TieredPageStore, ValetMempool)
from repro.core import device_ops
from repro.core.policies import POLICIES
from repro.models import transformer as T
from repro.serve import ValetServeEngine

CTX = T.ParallelCtx(remat=False, q_block=8, kv_block=8, loss_chunk=8)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(ARCHS["granite-3-8b"])
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab, size=8) for _ in range(6)]
    return cfg, params, prompts


def run_engine(params, cfg, prompts, policy, slots, zero):
    eng = ValetServeEngine(params, cfg, CTX, max_batch=3, max_seq=64,
                           page=4, pool_slots=slots,
                           policy=POLICIES[policy], zero_restore=zero)
    for p in prompts:
        eng.submit(p, max_new=10)
    reqs = eng.run(max_steps=500)
    outs = [r.tokens_out for r in sorted(reqs, key=lambda r: r.rid)]
    return outs, eng.stats, eng


# -- decode parity: zero-restore vs legacy, all policies -----------------------

@pytest.mark.parametrize("policy", ["valet", "infiniswap", "os-swap"])
def test_zero_restore_decode_parity_under_pressure(setup, policy):
    cfg, params, prompts = setup
    z_outs, z_stats, _ = run_engine(params, cfg, prompts, policy, 10, True)
    l_outs, l_stats, _ = run_engine(params, cfg, prompts, policy, 10, False)
    assert z_outs == l_outs, f"{policy}: zero-restore diverged from legacy"
    if policy == "valet":
        assert z_stats.pauses > 0                  # pressure actually hit
        assert z_stats.demoted_pages > 0
        assert z_stats.repointed_pages + z_stats.streamed_pages \
            == z_stats.restored_pages
        # restores that repoint cost nothing; the critical path can only
        # get cheaper than the copy-everything-back baseline
        assert z_stats.sim_time_us <= l_stats.sim_time_us
    else:
        # the flag is inert outside lazy migrate policies: identical
        # accounting, not just identical tokens
        assert z_stats.sim_time_us == l_stats.sim_time_us
        assert z_stats.demoted_pages == 0
        assert z_stats.repointed_pages == 0


# -- the repoint path performs zero bulk KV scatters ---------------------------

def test_repoint_path_never_bulk_copies(setup, monkeypatch):
    cfg, params, prompts = setup
    calls = {"bulk": 0}
    orig = device_ops.local_write_batch

    def counting(pool, ks, vs, slots):
        calls["bulk"] += 1
        return orig(pool, ks, vs, slots)

    monkeypatch.setattr(device_ops, "local_write_batch", counting)
    _, stats, _ = run_engine(params, cfg, prompts, "valet", 10, True)
    assert stats.restored_pages > 0                # restores happened
    assert stats.repointed_pages > 0               # ...mostly for free
    assert calls["bulk"] == 0, \
        "zero-restore must not bulk-scatter KV on the restore path"

    # the same counter fires on the legacy engine, so the zero above is a
    # property of the repoint path, not of a dead counter
    calls["bulk"] = 0
    _, l_stats, _ = run_engine(params, cfg, prompts, "valet", 10, False)
    assert l_stats.restored_pages > 0
    assert calls["bulk"] > 0


def test_demote_is_metadata_only(setup, monkeypatch):
    """Preemption in zero-restore mode moves no KV bytes: the device->host
    gather primitive stays uncalled until the background flush runs."""
    cfg, params, prompts = setup
    calls = {"to_host": 0}
    orig = device_ops.to_host_tier

    def counting(x):
        calls["to_host"] += 1
        return orig(x)

    monkeypatch.setattr(device_ops, "to_host_tier", counting)
    eng = ValetServeEngine(params, cfg, CTX, max_batch=2, max_seq=64,
                           page=4, pool_slots=32, policy=POLICIES["valet"])
    rid = eng.submit(prompts[0], max_new=8)
    req = eng._requests[rid]
    assert eng._admit(req)
    base = calls["to_host"]      # _read_seq_blob copies non-paged caches
    eng._preempt(req)
    # the per-slot (ring/ssm) blob save may gather, but no paged-KV spill:
    # demoted pages are not in the host tier and no flush cost accrued
    assert len(eng.host) == 0
    assert eng.stats.bg_time_us == 0.0
    assert eng.stats.demoted_pages == len(req.pages)
    eng._flush_demoted(None)
    assert calls["to_host"] > base                 # NOW the bytes move
    assert len(eng.host) == len(req.pages)


# -- pool generation counter + claim_batch -------------------------------------

def test_pool_free_gen_and_claim_batch():
    pool = ValetMempool(8, min_pages=8, max_pages=8)
    s0 = pool.alloc(100, 0)
    s1 = pool.alloc(101, 0)
    g0 = int(pool.gen[s0])
    assert pool.free_gen(s0) is None               # IN_USE: not claimable
    pool.release_batch([s0, s1])
    assert pool.free_gen(s0) == g0                 # FREE, gen unchanged
    assert pool.free_gen(10_000) is None           # out of range
    # reuse bumps the generation: a stale shadow can never validate
    g2 = int(pool.gen[s1])
    s2 = pool.alloc(102, 1)
    assert s2 in (s0, s1)
    assert int(pool.gen[s2]) == int({s0: g0, s1: g2}[s2]) + 1
    pool.release_batch([s2])
    # claim_batch pulls the exact slots back off the free list
    free_before = pool.free_count()
    pool.claim_batch([s1], [101], 2)
    assert pool.free_count() == free_before - 1
    assert pool.state[s1] == 1 and int(pool.owner[s1]) == 101
    assert pool.n_claimed == 1


def test_device_tier_shadow_lifecycle():
    dt = DeviceTier()
    gens = {3: 7, 4: 1}
    dt.demote([10, 11], [3, 4], [7, 1])
    assert 10 in dt and len(dt) == 2
    # valid claim consumes the entry and returns the slot
    assert dt.claim(10, lambda s: gens.get(s)) == 3
    assert 10 not in dt and dt.repoints == 1
    # generation mismatch (slot reused): entry consumed, no slot
    gens[4] = 2
    assert dt.claim(11, lambda s: gens.get(s)) is None
    assert dt.evictions == 1
    # evict_slots pops by slot (owner must secure dirty bytes first)
    dt.demote([12], [5], [9])
    assert dt.evict_slots([5]) == [(12, 5)]
    assert len(dt) == 0


# -- trace store: opt-in device tier, verified by invariants -------------------

def test_store_device_tier_repoints_and_keeps_invariants():
    st = TieredPageStore(config=OrchestrationConfig(
        pool_capacity=64, min_pool=64, device_tier=True))
    st.access_batch(np.arange(64), True)           # fill the pool exactly
    st.drain()                                     # all staged -> flushed
    st._reclaim(32)                                # demote 32 pages
    assert len(st.device) == 32
    st.access_batch(np.arange(64), False)          # read everything back
    assert st.stats.device_hits == 32              # demoted half repointed
    assert st.stats.local_hits == 64               # ...and classified local
    assert st.stats.host_hits == st.stats.remote_hits == 0
    InvariantChecker(st).check()
    # scalar path repoints too
    st._reclaim(8)
    demoted = [p for p in range(64) if p in st.device][:4]
    before = st.stats.device_hits
    for p in demoted:
        st.read(p)
    assert st.stats.device_hits == before + len(demoted)
    InvariantChecker(st).check()


def test_store_device_tier_off_by_default():
    st = TieredPageStore(config=OrchestrationConfig(pool_capacity=64))
    assert st.device is None
    st.access_batch(np.arange(100), True)
    assert st.stats.device_hits == 0
